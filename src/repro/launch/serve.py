"""Serving launcher: batched prefill + decode loop on the host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..distributed.ctx import activation_sharding
from ..models.registry import (decode_fn, forward_fn, init_params,
                               make_decode_state)
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    rng = np.random.default_rng(args.seed)

    with mesh, activation_sharding(mesh, seq_parallel=False):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)

        caches = make_decode_state(cfg, args.batch, args.cache_len,
                                   s_src=args.prompt_len)
        dfn = jax.jit(decode_fn(cfg))
        if cfg.family == "encdec":
            # encoder memory -> cross KV, then decode from BOS
            from ..models.encdec import encode, precompute_cross_kv
            src = jnp.asarray(rng.normal(
                0, 1, (args.batch, args.prompt_len, cfg.d_model)),
                jnp.float32)
            memory = encode(params, src, cfg)
            ck, cv = precompute_cross_kv(params, memory, cfg)
            caches = caches._replace(cross_k=ck, cross_v=cv)
            tok = jnp.zeros((args.batch, 1), jnp.int32)
            start_pos = 0
        else:
            # teacher-forced prefill: feed prompt tokens one step at a time
            # through the decode path (simple, exercises the cache), then
            # greedy-generate.
            tok = prompts[:, :1]
            for t in range(args.prompt_len - 1):
                _, caches = dfn(params, prompts[:, t:t + 1], caches,
                                jnp.int32(t))
            tok = prompts[:, -1:]
            start_pos = args.prompt_len - 1

        out_tokens = []
        t0 = time.perf_counter()
        for i in range(args.gen):
            logits, caches = dfn(params, tok, caches,
                                 jnp.int32(start_pos + i))
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            out_tokens.append(np.asarray(tok[:, 0]))
        dt = time.perf_counter() - t0
        gen = np.stack(out_tokens, axis=1)
        print(f"generated {gen.shape} tokens in {dt*1e3:.1f} ms "
              f"({args.gen*args.batch/dt:.1f} tok/s)")
        print("sample:", gen[0][:16])


if __name__ == "__main__":
    main()
