"""Deterministic, seedable fault injection for the resumable sweep fleet.

A fleet-scale claim ("a killed run resumes bitwise-identically") is only
testable if failures are *reproducible*. This module makes every failure
scenario a value: a ``FaultPlan`` is a frozen schedule of ``FaultEvent``
records — kill after quantum ``k``, kill before its checkpoint lands,
corrupt the checkpoint tmp-dir mid-write, optionally shrinking the
device pool — derived from a seed, so the same plan replays the same
crash sequence forever.

The ``FaultInjector`` is the live consumer the resumable drivers
(``repro.experiments.resumable``) thread through their quantum loop:

* ``quantum_computed()``   — after a quantum's results exist in memory
  but BEFORE its checkpoint: a ``kill_dirty`` event here loses the
  uncheckpointed work (the resume must recompute the quantum);
* ``hook(stage, tmpdir)``  — the ``save_checkpoint`` fault hook: a
  ``corrupt`` event truncates the half-written ``arrays.npz`` and dies
  mid-write (the atomic-rename contract must keep the previous
  checkpoint restorable);
* ``quantum_checkpointed()`` — after the checkpoint is published: a
  ``kill`` event here is the clean crash (resume skips the quantum).

Faults surface as ``HostLoss`` — the supervisor loop catches it, shrinks
the healthy pool by ``devices_lost``, re-plans the mesh and restores.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

__all__ = ["FAULT_KINDS", "FaultEvent", "FaultInjector", "FaultPlan",
           "HostLoss"]

# the three failure modes of a checkpointed quantum loop, in lifecycle
# order: crash before the checkpoint (work lost), crash inside the
# checkpoint write (tmp dir corrupt), crash after publish (clean)
FAULT_KINDS = ("kill_dirty", "corrupt", "kill")


class HostLoss(RuntimeError):
    """A simulated host/process death mid-run.

    ``devices_lost`` is how many devices leave the healthy pool with the
    host (0 = the process dies but its devices come back on restart);
    ``quantum`` records where the plan fired, for postmortems.
    """

    def __init__(self, message: str, *, devices_lost: int = 0,
                 quantum: Optional[int] = None):
        super().__init__(message)
        self.devices_lost = int(devices_lost)
        self.quantum = quantum


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled failure: ``kind`` fires at quantum ``quantum``.

    ``kind`` is one of ``FAULT_KINDS``; ``devices_lost`` shrinks the
    supervisor's device pool when the event fires (elastic re-mesh).
    """

    kind: str
    quantum: int
    devices_lost: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")
        if self.quantum < 0:
            raise ValueError(f"quantum must be >= 0, got {self.quantum}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A reproducible failure schedule (events sorted by quantum).

    Build explicitly from events, or randomized-but-deterministic via
    ``FaultPlan.random(seed, n_quanta)`` — the test suite's source of
    "killed at >= 3 randomized boundaries".
    """

    events: tuple[FaultEvent, ...]

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.quantum)))

    @classmethod
    def random(cls, seed: int, n_quanta: int, *, kills: int = 3,
               kinds: Sequence[str] = FAULT_KINDS,
               max_devices_lost: int = 0) -> "FaultPlan":
        """``kills`` failures at distinct random quanta in
        ``[0, n_quanta)``, kinds drawn from ``kinds``, each losing
        ``0..max_devices_lost`` devices — all a pure function of
        ``seed``."""
        rng = np.random.default_rng(seed)
        n_ev = max(0, min(int(kills), int(n_quanta)))
        quanta = sorted(rng.choice(int(n_quanta), size=n_ev,
                                   replace=False).tolist())
        events = []
        for q in quanta:
            kind = kinds[int(rng.integers(len(kinds)))]
            lost = (int(rng.integers(max_devices_lost + 1))
                    if max_devices_lost > 0 else 0)
            events.append(FaultEvent(kind=kind, quantum=int(q),
                                     devices_lost=lost))
        return cls(events=tuple(events))

    def injector(self) -> "FaultInjector":
        """A fresh live consumer of this plan (supervisor-owned: one
        injector survives across restart attempts so each event fires
        exactly once)."""
        return FaultInjector(self)


class FaultInjector:
    """Fires a ``FaultPlan``'s events at the driver's lifecycle points.

    Events are consumed strictly in order; an event fires at the first
    matching lifecycle point whose quantum counter has reached its
    scheduled quantum (so a plan built for more quanta than a run has
    simply never fires its tail). ``fired`` records the consumed events
    for assertions and postmortems.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.pending: list[FaultEvent] = list(plan.events)
        self.fired: list[FaultEvent] = []
        self.quantum = 0

    def _due(self, kind: str) -> Optional[FaultEvent]:
        if self.pending:
            ev = self.pending[0]
            if ev.kind == kind and ev.quantum <= self.quantum:
                return ev
        return None

    def _fire(self, ev: FaultEvent, why: str) -> None:
        self.pending.pop(0)
        self.fired.append(ev)
        raise HostLoss(
            f"injected {ev.kind} scheduled at quantum {ev.quantum} ({why})",
            devices_lost=ev.devices_lost, quantum=ev.quantum)

    def on_resume(self, quantum: int) -> None:
        """Re-align the quantum counter after a restore (the supervisor
        calls this with the restored driver's next quantum)."""
        self.quantum = int(quantum)

    def quantum_computed(self) -> None:
        """Lifecycle point: quantum results exist, checkpoint not yet
        written — ``kill_dirty`` loses the uncheckpointed work here."""
        ev = self._due("kill_dirty")
        if ev is not None:
            self._fire(ev, "uncheckpointed quantum lost")

    def hook(self, stage: str, tmpdir) -> None:
        """``save_checkpoint`` fault hook: a ``corrupt`` event truncates
        the half-written array archive in the tmp dir and dies mid-write
        — atomic publish must keep the previous checkpoint intact."""
        ev = self._due("corrupt")
        if ev is not None and stage == "arrays":
            p = Path(tmpdir) / "arrays.npz"
            raw = p.read_bytes()
            p.write_bytes(raw[:max(1, len(raw) // 2)])
            self._fire(ev, "crashed mid-checkpoint-write, tmp truncated")

    def quantum_checkpointed(self) -> None:
        """Lifecycle point: checkpoint published — ``kill`` is the clean
        crash (resume continues from the very next quantum). Advances
        the quantum counter."""
        ev = self._due("kill")
        self.quantum += 1
        if ev is not None:
            self._fire(ev, "killed after checkpoint publish")
