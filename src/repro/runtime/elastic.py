"""Elastic scaling: re-mesh a running job when the device pool changes.

At fleet scale, node failures shrink the healthy pool and repaired nodes
rejoin. The elastic protocol here:

1. ``plan_mesh(n_devices)`` — choose the largest supportable (data, model)
   grid (model-parallel degree is preserved when possible so parameter
   shards stay compatible; data parallelism absorbs the change).
2. ``reshard(tree, old→new shardings)`` — device_put the live state onto
   the new mesh (GSPMD moves only the bytes that actually change owner).
3. The caller re-lowers its step function for the new mesh and continues
   from the in-memory state (or restores the latest checkpoint if the
   failure lost device memory).

The gating invariant: global batch is unchanged, so a re-meshed run is
statistically identical to an uninterrupted one (only step time changes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_mesh(n_devices: int, *, model_parallel: int = 16,
              min_model_parallel: int = 1) -> MeshPlan:
    """Largest (data, model) grid fitting the healthy pool.

    Keeps the requested model-parallel degree if any multiple of it fits;
    otherwise degrades model parallelism by powers of two (parameters are
    re-sharded — costly but correct).
    """
    mp = model_parallel
    while mp >= max(min_model_parallel, 1):
        data = n_devices // mp
        if data >= 1:
            return MeshPlan(shape=(data, mp), axes=("data", "model"))
        mp //= 2
    raise ValueError(f"cannot build a mesh from {n_devices} devices")


def plan_app_mesh(n_devices: int) -> MeshPlan:
    """1-D ``("app",)`` plan over the healthy pool — the sweep engine's
    mesh. App lanes are pure data parallelism (they never communicate),
    so ANY device count works: the engine pads the app axis up to it."""
    if n_devices < 1:
        raise ValueError(f"cannot build a mesh from {n_devices} devices")
    return MeshPlan(shape=(int(n_devices),), axes=("app",))


def plan_app_trial_mesh(n_devices: int, *, app_devices: int = 1) -> MeshPlan:
    """2-D ``("app", "trial")`` plan for the streaming trial engine.

    Keeps the requested app-parallel degree when the pool allows it
    (clamped to the pool); the trial axis absorbs the change — exactly
    the data-axis-absorbs-shrink rule of ``plan_mesh``, with "trial" in
    the data role. Devices that do not fill the rectangle idle.
    """
    if n_devices < 1:
        raise ValueError(f"cannot build a mesh from {n_devices} devices")
    app = max(1, min(int(app_devices), int(n_devices)))
    trial = int(n_devices) // app
    return MeshPlan(shape=(app, trial), axes=("app", "trial"))


def build_mesh(plan: MeshPlan,
               devices: Optional[Sequence] = None) -> Mesh:
    devs = list(devices if devices is not None else jax.devices())
    need = plan.n_devices
    if len(devs) < need:
        raise ValueError(f"plan needs {need} devices, have {len(devs)}")
    grid = np.asarray(devs[:need]).reshape(plan.shape)
    return Mesh(grid, plan.axes)


def reshard(tree: PyTree, new_shardings: PyTree) -> PyTree:
    """Move live state onto a new mesh (elastic shrink/grow)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, new_shardings)


@dataclasses.dataclass
class ElasticRunner:
    """Bookkeeping for failure-driven re-meshing.

    ``on_pool_change(n_devices)`` returns the new mesh plan; callers then
    reshard state + re-lower. Tracks topology history for postmortems.

    ``mesh_kind`` selects the planner: ``"data_model"`` (the default
    training-style grid, degraded via ``model_parallel``), ``"app"``
    (the sweep engine's 1-D mesh) or ``"app_trial"`` (the streaming
    trial engine's 2-D mesh, app degree held at ``app_devices``).
    """

    model_parallel: int = 16
    mesh_kind: str = "data_model"
    app_devices: int = 1
    history: list = dataclasses.field(default_factory=list)

    def on_pool_change(self, n_devices: int) -> MeshPlan:
        if self.mesh_kind == "app":
            plan = plan_app_mesh(n_devices)
        elif self.mesh_kind == "app_trial":
            plan = plan_app_trial_mesh(n_devices,
                                       app_devices=self.app_devices)
        elif self.mesh_kind == "data_model":
            plan = plan_mesh(n_devices, model_parallel=self.model_parallel)
        else:
            raise ValueError(f"unknown mesh_kind {self.mesh_kind!r}")
        self.history.append({"n_devices": n_devices,
                             "shape": plan.shape})
        return plan
