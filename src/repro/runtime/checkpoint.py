"""Fault-tolerant checkpointing (pure JAX / numpy, no orbax dependency).

Design points for the 1000+-node regime:

* **atomicity** — checkpoints are written to ``step_N.tmp/`` and renamed
  into place; a crash mid-write never corrupts the latest checkpoint;
* **manifest** — a JSON manifest records the pytree structure, per-leaf
  dtypes/shapes and the data seed/step, so restore can validate before
  loading and the data pipeline resumes at the exact batch;
* **sharding-aware restore** — leaves are ``device_put`` against the
  *current* mesh's shardings, so a job restarted on a different topology
  (elastic re-mesh) re-shards transparently;
* **retention** — keep the last K checkpoints (default 3).
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "::"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str | Path, step: int, tree: PyTree,
                    *, extra: Optional[dict] = None, keep: int = 3) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **{k.replace("/", _SEP): v
                                    for k, v in flat.items()})
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic publish

    # retention
    ckpts = sorted((p for p in directory.glob("step_*")
                    if not p.name.endswith(".tmp")),
                   key=lambda p: int(p.name.split("_")[1]))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str | Path, template: PyTree,
                       *, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> tuple[PyTree, dict]:
    """Restore into the structure of ``template``. ``shardings`` (a pytree
    of jax.sharding.Sharding matching template) re-shards for the current
    mesh; None keeps host arrays."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")

    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_leaves_with_path(template)]
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_t))

    out = []
    for key, tmpl, sh in zip(paths, leaves_t, shard_leaves):
        k = key.replace("/", _SEP)
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[k]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {tmpl.shape}")
        arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
