"""Fault-tolerant checkpointing (pure JAX / numpy, no orbax dependency).

Design points for the 1000+-node regime:

* **atomicity** — checkpoints are written to ``step_N.tmp/`` and renamed
  into place; a crash mid-write never corrupts the latest checkpoint
  (``fault_hook`` lets the fault-injection harness die *inside* the
  write to prove it);
* **manifest** — a JSON manifest records the pytree structure, per-leaf
  dtypes/shapes and caller metadata, and restore validates the manifest
  — expected run identity AND every leaf's shape — BEFORE touching the
  array archive, so a mismatched or half-garbage checkpoint fails fast
  as ``ManifestMismatch`` instead of loading;
* **sharding-aware restore** — leaves are ``device_put`` against the
  *current* mesh's shardings, so a job restarted on a different topology
  (elastic re-mesh) re-shards transparently;
* **retention** — keep the last K checkpoints (default 3);
* **MemoBank snapshots** — ``save_memobank``/``restore_memobank`` wrap
  the sweep engine's memo cache (mask + value blocks, charge matrix,
  ledger totals, ``version``) so a resumed sweep's cost accounting is
  bitwise-equal to an uninterrupted run's.
"""

from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

PyTree = Any

_SEP = "::"


class ManifestMismatch(ValueError):
    """The checkpoint manifest does not match what the caller expects
    (wrong run identity, missing leaves, or leaf-shape drift) — raised
    BEFORE any array data is read."""


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str | Path, step: int, tree: PyTree,
                    *, extra: Optional[dict] = None, keep: int = 3,
                    fault_hook: Optional[Callable[[str, Path], None]] = None
                    ) -> Path:
    """Write ``tree`` + ``extra`` metadata as ``step_N/``, atomically.

    ``fault_hook(stage, tmpdir)`` is called mid-write — after the array
    archive lands (``stage="arrays"``) and after the manifest lands
    (``stage="manifest"``), both BEFORE the atomic rename — so the
    fault-injection harness can corrupt the tmp dir and crash exactly
    where a real host would: the previous checkpoint must survive.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **{k.replace("/", _SEP): v
                                    for k, v in flat.items()})
    if fault_hook is not None:
        fault_hook("arrays", tmp)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if fault_hook is not None:
        fault_hook("manifest", tmp)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                       # atomic publish

    # retention
    ckpts = sorted((p for p in directory.glob("step_*")
                    if not p.name.endswith(".tmp")),
                   key=lambda p: int(p.name.split("_")[1]))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def _jsonable(value):
    """Round-trip through JSON so tuples/np scalars compare equal to what
    the manifest stored."""
    return json.loads(json.dumps(value, default=str))


def read_manifest(directory: str | Path, *, step: Optional[int] = None
                  ) -> dict:
    """The manifest dict of ``step`` (default: latest) — metadata-only
    access, never touches the array archive."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    return json.loads(
        (directory / f"step_{step}" / "manifest.json").read_text())


def restore_checkpoint(directory: str | Path, template: PyTree,
                       *, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None,
                       expect: Optional[dict] = None
                       ) -> tuple[PyTree, dict]:
    """Restore into the structure of ``template``.

    Validation is manifest-first: ``expect`` (a dict that must match the
    manifest's ``extra`` key-for-key — the run-identity contract) and
    every template leaf's presence + shape are checked against the JSON
    manifest BEFORE ``arrays.npz`` is opened; any mismatch raises
    ``ManifestMismatch`` without reading array data. ``shardings`` (a
    pytree of jax.sharding.Sharding matching template) re-shards for the
    current mesh; None keeps host arrays.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step_{step}"
    manifest = json.loads((path / "manifest.json").read_text())

    if expect:
        stored = manifest.get("extra", {})
        for key, want in expect.items():
            got = stored.get(key)
            if got != _jsonable(want):
                raise ManifestMismatch(
                    f"checkpoint step {step} was written by a different "
                    f"run: extra[{key!r}] is {got!r}, expected "
                    f"{_jsonable(want)!r}")

    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_leaves_with_path(template)]
    man_leaves = manifest["leaves"]
    for key, tmpl in zip(paths, leaves_t):
        k = key.replace("/", _SEP)
        if k not in man_leaves:
            raise ManifestMismatch(f"checkpoint missing leaf {key}")
        if tuple(man_leaves[k]["shape"]) != tuple(np.shape(tmpl)):
            raise ManifestMismatch(
                f"shape mismatch for {key}: "
                f"{tuple(man_leaves[k]['shape'])} vs {np.shape(tmpl)}")

    data = np.load(path / "arrays.npz")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_t))
    out = []
    for key, tmpl, sh in zip(paths, leaves_t, shard_leaves):
        arr = data[key.replace("/", _SEP)]
        arr = arr.astype(np.asarray(tmpl).dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


# ---------------------------------------------------------------- MemoBank
def save_memobank(directory: str | Path, step: int, bank,
                  *, extra: Optional[dict] = None, keep: int = 3,
                  fault_hook=None) -> Path:
    """Snapshot a ``repro.simcpu.MemoBank`` (mask + CPI blocks, charge
    matrix, hit/miss counters, per-app ledger totals, ``version``) as one
    atomic checkpoint; the bank's identity metadata (app names, region
    counts, config reprs) rides in the manifest for restore validation."""
    tree, meta = bank.state()
    merged = dict(extra or {})
    merged["memobank"] = meta
    return save_checkpoint(directory, step, tree, extra=merged, keep=keep,
                           fault_hook=fault_hook)


def restore_memobank(directory: str | Path, bank, *,
                     universe: Sequence = (), step: Optional[int] = None,
                     expect: Optional[dict] = None) -> dict:
    """Restore a ``save_memobank`` snapshot INTO ``bank`` (same apps, any
    config-column order — ``universe`` supplies the config objects the
    manifest's reprs resolve against). Validates manifest identity before
    loading; returns the checkpoint's ``extra`` metadata."""
    manifest = read_manifest(directory, step=step)
    meta = manifest.get("extra", {}).get("memobank")
    if meta is None:
        raise ManifestMismatch(
            f"checkpoint in {directory} holds no memobank snapshot")
    bank.prepare_restore(meta, universe=universe)
    tree, _ = bank.state()
    restored, extra = restore_checkpoint(
        directory, tree, step=step, expect=expect)
    bank.load_state(restored, meta, universe=universe)
    return extra
