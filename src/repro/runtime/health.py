"""Step-time health monitoring with stratified sampled profiling.

This is the paper's technique feeding back into the training runtime
(DESIGN.md §2.3): per-step wall times form a population; cheap features
(step index phase, data-shape bucket, recent loss) are the phase-1
auxiliary variable; occasionally the runtime takes a *stratified* sample of
steps to profile in depth (host callbacks, timing breakdowns) instead of
profiling uniformly — fewer profiled steps for the same confidence on the
mean step time, and collapsed-strata CIs when only one profile per stratum
is affordable.

``StragglerDetector`` additionally flags steps slower than
median + k·IQR — the restart/straggler-mitigation trigger at fleet scale.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from ..core.sampling import (collapsed_strata_estimate, srs_estimate,
                             stratified_estimate_from_samples)


@dataclasses.dataclass
class StepTimer:
    """Rolling step-duration tracker."""

    window: int = 512
    _times: deque = dataclasses.field(default_factory=lambda: deque())
    _last: Optional[float] = None

    def tick(self) -> Optional[float]:
        now = time.perf_counter()
        dt = None
        if self._last is not None:
            dt = now - self._last
            self._times.append(dt)
            if len(self._times) > self.window:
                self._times.popleft()
        self._last = now
        return dt

    def record(self, dt: float) -> None:
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.popleft()

    @property
    def times(self) -> np.ndarray:
        return np.asarray(self._times)


@dataclasses.dataclass
class StragglerDetector:
    """Flag outlier steps (median + k*IQR rule over a rolling window)."""

    k: float = 3.0
    min_samples: int = 32

    def is_straggler(self, times: np.ndarray, dt: float) -> bool:
        if times.size < self.min_samples:
            return False
        q1, med, q3 = np.percentile(times, [25, 50, 75])
        return dt > med + self.k * max(q3 - q1, 1e-9)


@dataclasses.dataclass
class QuantumHealth:
    """Per-quantum wall-time monitor for the resumable sweep supervisor.

    The checkpointed drivers report ``(quantum_index, seconds)`` after
    every restart quantum; durations feed a rolling ``StepTimer`` window
    and the median+k·IQR ``StragglerDetector``, so a supervised fleet
    run ends with a postmortem trace: which quanta ran, how long, and
    which were straggling *before* any fault fired.
    """

    timer: StepTimer = dataclasses.field(default_factory=StepTimer)
    detector: StragglerDetector = dataclasses.field(
        default_factory=StragglerDetector)
    quanta: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    def record(self, quantum: int, seconds: float) -> bool:
        """Fold one quantum's duration in; True if it straggled."""
        slow = self.detector.is_straggler(self.timer.times, seconds)
        self.timer.record(seconds)
        self.quanta.append({"quantum": int(quantum),
                            "seconds": float(seconds),
                            "straggler": bool(slow)})
        if slow:
            self.stragglers.append((int(quantum), float(seconds)))
        return slow

    def summary(self) -> dict:
        """Totals for reports: quanta recorded, wall seconds, stragglers."""
        total = float(sum(q["seconds"] for q in self.quanta))
        return {"quanta": len(self.quanta), "seconds": total,
                "stragglers": len(self.stragglers)}


def stratified_steptime_estimate(times, strata_labels, *, num_strata: int,
                                 confidence: float = 0.95):
    """Mean step time + CI from a stratified sample of profiled steps."""
    return stratified_estimate_from_samples(
        np.asarray(times), np.asarray(strata_labels),
        num_strata=num_strata, confidence=confidence)


def one_per_stratum_steptime_ci(times_per_stratum, weights, *,
                                confidence: float = 0.95):
    """Collapsed-strata CI when only one profiled step per stratum exists
    (the cheapest profiling budget — paper Section V.A.3)."""
    return collapsed_strata_estimate(np.asarray(times_per_stratum),
                                     np.asarray(weights),
                                     confidence=confidence)


def srs_steptime_estimate(times, *, confidence: float = 0.95):
    return srs_estimate(np.asarray(times), confidence=confidence)
