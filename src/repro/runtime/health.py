"""Step-time health monitoring with stratified sampled profiling.

This is the paper's technique feeding back into the training runtime
(DESIGN.md §2.3): per-step wall times form a population; cheap features
(step index phase, data-shape bucket, recent loss) are the phase-1
auxiliary variable; occasionally the runtime takes a *stratified* sample of
steps to profile in depth (host callbacks, timing breakdowns) instead of
profiling uniformly — fewer profiled steps for the same confidence on the
mean step time, and collapsed-strata CIs when only one profile per stratum
is affordable.

``StragglerDetector`` additionally flags steps slower than
median + k·IQR — the restart/straggler-mitigation trigger at fleet scale.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from ..core.sampling import (collapsed_strata_estimate, srs_estimate,
                             stratified_estimate_from_samples)


@dataclasses.dataclass
class StepTimer:
    """Rolling step-duration tracker."""

    window: int = 512
    _times: deque = dataclasses.field(default_factory=lambda: deque())
    _last: Optional[float] = None

    def tick(self) -> Optional[float]:
        now = time.perf_counter()
        dt = None
        if self._last is not None:
            dt = now - self._last
            self._times.append(dt)
            if len(self._times) > self.window:
                self._times.popleft()
        self._last = now
        return dt

    def record(self, dt: float) -> None:
        self._times.append(dt)
        if len(self._times) > self.window:
            self._times.popleft()

    @property
    def times(self) -> np.ndarray:
        return np.asarray(self._times)


@dataclasses.dataclass
class StragglerDetector:
    """Flag outlier steps (median + k*IQR rule over a rolling window)."""

    k: float = 3.0
    min_samples: int = 32

    def is_straggler(self, times: np.ndarray, dt: float) -> bool:
        if times.size < self.min_samples:
            return False
        q1, med, q3 = np.percentile(times, [25, 50, 75])
        return dt > med + self.k * max(q3 - q1, 1e-9)


def stratified_steptime_estimate(times, strata_labels, *, num_strata: int,
                                 confidence: float = 0.95):
    """Mean step time + CI from a stratified sample of profiled steps."""
    return stratified_estimate_from_samples(
        np.asarray(times), np.asarray(strata_labels),
        num_strata=num_strata, confidence=confidence)


def one_per_stratum_steptime_ci(times_per_stratum, weights, *,
                                confidence: float = 0.95):
    """Collapsed-strata CI when only one profiled step per stratum exists
    (the cheapest profiling budget — paper Section V.A.3)."""
    return collapsed_strata_estimate(np.asarray(times_per_stratum),
                                     np.asarray(weights),
                                     confidence=confidence)


def srs_steptime_estimate(times, *, confidence: float = 0.95):
    return srs_estimate(np.asarray(times), confidence=confidence)
