"""seamless-m4t-large-v2 — enc-dec multimodal backbone [arXiv:2308.11596].

24L (x2 stacks) d_model=1024 16H (MHA) d_ff=8192 vocab=256206. The speech
frontend is a stub: ``input_specs`` supplies precomputed frame embeddings
(b, s_src, d_model) to the encoder.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, encoder_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=16, d_ff=8192, vocab=256206, head_dim=64,
        embed_frontend=True)


def smoke() -> ModelConfig:
    return smoke_variant(full(), head_dim=64, n_heads=4, n_kv_heads=4)


register("seamless-m4t-large-v2", full, smoke)
