"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The VQ image-token
frontend is a stub: image patches arrive as token ids in the shared vocab
(early fusion), so the backbone is a standard dense GQA decoder.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b", family="dense",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22016, vocab=65536)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("chameleon-34b", full, smoke)
