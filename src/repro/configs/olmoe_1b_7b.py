"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1024 vocab=50304.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304, moe_experts=64, moe_topk=8)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("olmoe-1b-7b", full, smoke)
