"""granite-8b — llama-arch code model [arXiv:2405.04324].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense",
        n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=49152)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("granite-8b", full, smoke)
