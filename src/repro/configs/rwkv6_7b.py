"""rwkv6-7b "Finch" — attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=4096 d_ff=14336 vocab=65536; rwkv head_dim 64 (64 heads).
Sub-quadratic: O(1) decode state, runs long_500k.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab=65536, rwkv_head_dim=64)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("rwkv6-7b", full, smoke)
