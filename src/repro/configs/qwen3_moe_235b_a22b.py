"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
        d_ff=1536, vocab=151936, moe_experts=128, moe_topk=8)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("qwen3-moe-235b-a22b", full, smoke)
