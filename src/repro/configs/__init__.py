"""Architecture configs (one module per assigned arch)."""

from . import (chameleon_34b, command_r_35b, granite_8b,  # noqa: F401
               internlm2_20b, llama3_2_3b, olmoe_1b_7b,
               qwen3_moe_235b_a22b, recurrentgemma_2b, rwkv6_7b,
               seamless_m4t_large_v2)
from .base import (SHAPE_BY_NAME, SHAPES, ShapeCell, cells_for,  # noqa: F401
                   get_config, list_archs, smoke_variant)

ALL_ARCHS = list_archs()
