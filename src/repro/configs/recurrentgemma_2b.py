"""recurrentgemma-2b — RG-LRU + local attention, (R,R,A) [arXiv:2402.19427].

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000; local window
2048; rnn width 2560. Sub-quadratic: runs long_500k with a ring-buffer
local cache + O(1) recurrent state.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        d_ff=7680, vocab=256000, head_dim=256,
        window=2048, rnn_width=2560)


def smoke() -> ModelConfig:
    return smoke_variant(full(), n_heads=4, n_kv_heads=1, head_dim=64)


register("recurrentgemma-2b", full, smoke)
