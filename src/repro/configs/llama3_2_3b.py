"""llama3.2-3b — small llama3 [hf:meta-llama/Llama-3.2-1B].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("llama3.2-3b", full, smoke)
