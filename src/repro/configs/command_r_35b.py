"""command-r-35b — dense GQA, no biases [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""

from ..models.common import ModelConfig
from .base import register, smoke_variant


def full() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=22528, vocab=256000)


def smoke() -> ModelConfig:
    return smoke_variant(full())


register("command-r-35b", full, smoke)
