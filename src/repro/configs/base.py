"""Architecture config registry + shape cells.

One ``full()`` (exact published config, bf16) and one ``smoke()`` (reduced,
f32, CPU-runnable) per assigned architecture. Shapes follow the assignment:

    train_4k     seq 4096  global_batch 256   (train_step)
    prefill_32k  seq 32768 global_batch 32    (prefill forward)
    decode_32k   1 token, KV/state at 32768, batch 128  (serve_step)
    long_500k    1 token, state at 524288, batch 1      (serve_step,
                 sub-quadratic archs only)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}

_REGISTRY: dict[str, dict[str, Callable[[], ModelConfig]]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = {"full": full, "smoke": smoke}


def _ensure_registered() -> None:
    if not _REGISTRY:
        from . import ALL_ARCHS  # noqa: F401 — triggers module imports


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    _ensure_registered()
    entry = _REGISTRY.get(arch_id)
    if entry is None:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return entry["smoke" if smoke else "full"]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def cells_for(cfg: ModelConfig) -> list[ShapeCell]:
    """Applicable shape cells (long_500k only for sub-quadratic archs)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # skipped per DESIGN.md §2.4
        out.append(s)
    return out


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a full config to a CPU-runnable smoke config (same family)."""
    base = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.family != "hybrid" else 6,
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_ff=512,
        vocab=512,
        head_dim=64,
        dtype=jnp.float32,
    )
    if cfg.moe_experts:
        base["moe_experts"] = 8
        base["moe_topk"] = min(cfg.moe_topk, 2)
    if cfg.window:
        base["window"] = 64
    if cfg.rnn_width:
        base["rnn_width"] = 256
    if cfg.encoder_layers:
        base["encoder_layers"] = 2
        base["n_layers"] = 2
    if cfg.family == "ssm":
        base["rwkv_head_dim"] = 32
        base["n_heads"] = 8
        base["n_kv_heads"] = 8
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
