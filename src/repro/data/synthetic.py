"""Deterministic synthetic token pipeline.

Seeded, stateless batch generation: batch ``i`` is a pure function of
(seed, step), so a restarted job regenerates the exact token stream from
its checkpointed step — the data-side half of fault-tolerant training. A
zipfian unigram marginal plus a short-range Markov blend give non-trivial
(learnable) statistics so loss curves actually descend in the examples.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def _probs(self) -> np.ndarray:
        p = 1.0 / np.arange(1, self.vocab + 1) ** self.zipf_a
        return (p / p.sum()).astype(np.float32)

    def batch(self, step: int) -> dict[str, jax.Array]:
        """Tokens + next-token labels for one step (host-side numpy)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        probs = self._probs()
        b, s = self.global_batch, self.seq_len
        base = rng.choice(self.vocab, size=(b, s + 1), p=probs)
        # short-range structure: with prob .5 repeat the previous token + 1
        rep = rng.random((b, s + 1)) < 0.5
        for j in range(1, s + 1):
            base[:, j] = np.where(rep[:, j],
                                  (base[:, j - 1] + 1) % self.vocab,
                                  base[:, j])
        return {
            "tokens": jnp.asarray(base[:, :-1], jnp.int32),
            "labels": jnp.asarray(base[:, 1:], jnp.int32),
        }


@dataclasses.dataclass(frozen=True)
class SyntheticEncDec(SyntheticLM):
    d_model: int = 1024
    src_len: int = 256

    def batch(self, step: int) -> dict[str, jax.Array]:
        out = super().batch(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 1]))
        out["src_embeds"] = jnp.asarray(
            rng.normal(0, 1, (self.global_batch, self.src_len,
                              self.d_model)).astype(np.float32))
        return out


def make_pipeline(cfg, seq_len: int, global_batch: int, seed: int = 0):
    if cfg.family == "encdec":
        return SyntheticEncDec(vocab=cfg.vocab, seq_len=seq_len,
                               global_batch=global_batch, seed=seed,
                               d_model=cfg.d_model,
                               src_len=min(seq_len, 256))
    return SyntheticLM(vocab=cfg.vocab, seq_len=seq_len,
                       global_batch=global_batch, seed=seed)
