"""Train / serve step builders with explicit shardings.

``build_train_step`` returns a function suitable for ``jax.jit`` with
in/out shardings derived from distributed/sharding.py; ``lower_train_step``
does the AOT ``.lower()`` against ShapeDtypeStructs (the dry-run path —
nothing is allocated).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ShapeCell
from ..distributed.ctx import activation_sharding
from ..distributed.sharding import (batch_specs, cache_specs,
                                    opt_state_specs, param_specs)
from ..models.common import ModelConfig
from ..models.registry import (decode_fn, init_params, loss_fn,
                               make_decode_state)
from ..optim.adamw import AdamW, AdamWState, apply_updates


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
               "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "encdec":
            out["src_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), cfg.dtype)
            # enc-dec trains on (src frames -> tgt tokens); keep both at s
        return out
    if cell.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "encdec":
            out["src_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), cfg.dtype)
        return out
    # decode: one new token against a cache of length s
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def default_microbatches(cfg: ModelConfig, cell: ShapeCell) -> int:
    """Gradient-accumulation depth: keep ~<=4k tokens x d_model-scaled
    activations per device; larger models accumulate more."""
    p = cfg.param_count()
    if cell.kind != "train":
        return 1
    if p >= 1e11:
        return 8      # §Perf: mb16 doubled the per-step collective traffic
    if p >= 3e10:
        return 8
    if p >= 8e9:
        return 4
    if p >= 2e9:
        return 2
    return 1


def make_train_fn(cfg: ModelConfig, opt: AdamW, *, microbatches: int = 1):
    lfn = loss_fn(cfg)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(lfn)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches,
                                    x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)

            def acc(carry, mbatch):
                gsum, lsum = carry
                l, g = jax.value_and_grad(lfn)(params, mbatch)
                gsum = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype),
                                    gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(
                lambda p_: jnp.zeros(p_.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g_: g_ / microbatches, gsum)
            loss = lsum / microbatches
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def lower_train_step(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                     opt: Optional[AdamW] = None, *, donate: bool = True,
                     microbatches: Optional[int] = None,
                     fsdp: Optional[bool] = None):
    """AOT-lower the jitted train step for a mesh (dry-run / deploy)."""
    if opt is None:
        # >=100B params: bf16 moments (PaLM/Gopher-style) — the f32 pair
        # alone would eat half of a v5e's HBM even at 256-way sharding.
        moment_dtype = jnp.bfloat16 if cfg.param_count() >= 1e11 \
            else jnp.float32
        opt = AdamW(moment_dtype=moment_dtype)
    if microbatches is None:
        microbatches = default_microbatches(cfg, cell)
    params = init_params(cfg, abstract=True)
    opt_state = opt.init(params, abstract=True)

    p_specs = param_specs(params, mesh)
    o_specs = AdamWState(step=P(), m=opt_state_specs(params, mesh),
                         v=opt_state_specs(params, mesh),
                         ef=None if opt_state.ef is None
                         else opt_state_specs(params, mesh))
    b_specs = batch_specs(cfg, mesh, "train")

    def sh(tree_specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                            is_leaf=lambda x: isinstance(x, P))

    step_fn = make_train_fn(cfg, opt, microbatches=microbatches)
    jitted = jax.jit(
        step_fn,
        in_shardings=(sh(p_specs), sh(o_specs), sh(b_specs)),
        out_shardings=(sh(p_specs), sh(o_specs), NamedSharding(mesh, P())),
        donate_argnums=(0, 1) if donate else (),
    )
    batch = input_specs(cfg, cell)
    with mesh, activation_sharding(mesh):
        lowered = jitted.lower(params, opt_state, batch)
    return lowered


def make_prefill_fn(cfg: ModelConfig):
    from ..models.registry import forward_fn
    fwd = forward_fn(cfg)

    def prefill(params, batch):
        logits = fwd(params, batch)
        # return only the last-position logits (sampling interface)
        return logits[:, -1, :]

    return prefill


def lower_prefill(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                  serving_params: bool = True):
    params = init_params(cfg, abstract=True)
    p_specs = param_specs(params, mesh, serving=serving_params)
    b_specs = batch_specs(cfg, mesh, "prefill")

    def sh(tree_specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                            is_leaf=lambda x: isinstance(x, P))

    jitted = jax.jit(make_prefill_fn(cfg),
                     in_shardings=(sh(p_specs), sh(b_specs)),
                     out_shardings=NamedSharding(mesh, P()))
    batch = input_specs(cfg, cell)
    with mesh, activation_sharding(mesh):
        lowered = jitted.lower(params, batch)
    return lowered


def make_serve_fn(cfg: ModelConfig):
    dfn = decode_fn(cfg)

    def serve_step(params, tokens, caches, pos):
        logits, caches = dfn(params, tokens, caches, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches

    return serve_step


def lower_serve_step(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                     serving_params: bool = True):
    """Decode step: one token, cache at cell.seq_len."""
    b, s = cell.global_batch, cell.seq_len
    params = init_params(cfg, abstract=True)
    caches = make_decode_state(cfg, b, s, s_src=min(s, 4096), abstract=True)
    p_specs = param_specs(params, mesh, serving=serving_params)
    c_specs = cache_specs(cfg, caches, mesh)

    def sh(tree_specs):
        return jax.tree.map(lambda s_: NamedSharding(mesh, s_), tree_specs,
                            is_leaf=lambda x: isinstance(x, P))

    from ..launch.mesh import axis_size, data_axes
    dp = data_axes(mesh)
    dpa = dp if len(dp) > 1 else dp[0]
    if b % max(axis_size(mesh, dp), 1):
        dpa = None                    # batch 1 (long-context): replicate
    tok_sh = NamedSharding(mesh, P(dpa, None))

    jitted = jax.jit(
        make_serve_fn(cfg),
        in_shardings=(sh(p_specs), tok_sh, sh(c_specs), None),
        out_shardings=(tok_sh, sh(c_specs)),
        donate_argnums=(2,),
    )
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    with mesh, activation_sharding(mesh, seq_parallel=False):
        lowered = jitted.lower(params, tokens, caches, pos)
    return lowered


def lower_cell(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
               opt: Optional[AdamW] = None,
               microbatches: Optional[int] = None,
               serving_params: bool = True,
               fsdp: Optional[bool] = None):
    """Dispatch on the cell kind (the dry-run entry point)."""
    if cell.kind == "train":
        return lower_train_step(cfg, cell, mesh, opt,
                                microbatches=microbatches, fsdp=fsdp)
    if cell.kind == "prefill":
        return lower_prefill(cfg, cell, mesh,
                             serving_params=serving_params)
    return lower_serve_step(cfg, cell, mesh,
                            serving_params=serving_params)
