"""Two-phase stratified sampled evaluation for LMs (the paper's technique
as a first-class training-framework feature — DESIGN.md §2.3).

Estimating eval loss over a large heterogeneous corpus is the LM analogue
of estimating CPI over an application's regions:

  phase 1   forward a large random sample of eval batches once on the
            *current* checkpoint, recording a cheap per-batch feature
            vector (loss, token entropy, mean seq length, OOV rate,
            router-load stats for MoE) — the "RFV";
  stratify  k-means on the standardized features;
  phase 2   day-to-day evals forward only one batch per stratum (centroid
            selection); periodic CI checks sample a few batches per
            stratum and apply the two-phase formulas (eq. 5/6).

Same estimators, same code path as the simcpu reproduction — the point of
the framework is that ``repro.core.sampling`` is substrate-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..core.clustering import Standardizer, kmeans
from ..core.sampling import (Estimate, select_centroid, summarize_strata,
                             two_phase_estimate, weighted_point_estimate)


@dataclasses.dataclass
class SampledEval:
    """``eval_batch(idx) -> (loss, feature_vector)`` over a corpus of
    ``n_batches`` batches; the driver owns phase-1 sampling, stratification
    and the cheap phase-2 estimators."""

    n_batches: int
    eval_batch: Callable[[int], tuple[float, np.ndarray]]
    num_strata: int = 16
    seed: int = 0

    # phase-1 artifacts
    _idx1: Optional[np.ndarray] = None
    _losses1: Optional[np.ndarray] = None
    _labels: Optional[np.ndarray] = None
    _weights: Optional[np.ndarray] = None
    _selected: Optional[list] = None

    def characterize(self, n_phase1: int) -> Estimate:
        rng = np.random.default_rng(self.seed)
        self._idx1 = rng.choice(self.n_batches,
                                size=min(n_phase1, self.n_batches),
                                replace=False)
        losses, feats = [], []
        for i in self._idx1:
            loss, f = self.eval_batch(int(i))
            losses.append(loss)
            feats.append(np.asarray(f, np.float64))
        self._losses1 = np.asarray(losses)
        feats = np.stack(feats)

        _, z = Standardizer.fit_transform(feats)
        z = np.asarray(z)
        km = kmeans(z, min(self.num_strata, len(self._idx1)), seed=self.seed)
        self._labels = km.labels
        counts = np.bincount(km.labels, minlength=km.centroids.shape[0])
        self._weights = counts / counts.sum()
        self._selected = select_centroid(km.labels, z, km.centroids)
        from ..core.sampling import srs_estimate
        return srs_estimate(self._losses1)

    def quick_estimate(self) -> float:
        """Day-to-day eval: one forward per stratum (centroid batches)."""
        if self._selected is None:
            raise RuntimeError("characterize() first")
        y = np.array([self.eval_batch(int(self._idx1[s[0]]))[0]
                      for s in self._selected if s.size])
        sel = [np.array([i]) for i in range(len(y))]
        w = self._weights[[h for h, s in enumerate(self._selected)
                           if s.size]]
        return weighted_point_estimate(sel, y, w / w.sum())

    def ci_check(self, per_stratum: int = 4,
                 confidence: float = 0.95) -> Estimate:
        """Periodic multi-batch-per-stratum CI (paper step 4b)."""
        rng = np.random.default_rng(self.seed + 1)
        ys, labs = [], []
        for h in range(int(self._weights.shape[0])):
            pool = self._idx1[self._labels == h]
            if pool.size == 0:
                continue
            take = rng.choice(pool, size=min(per_stratum, pool.size),
                              replace=False)
            for i in take:
                ys.append(self.eval_batch(int(i))[0])
                labs.append(h)
        summaries = summarize_strata(np.asarray(ys), np.asarray(labs),
                                     weights=self._weights,
                                     num_strata=self._weights.shape[0])
        return two_phase_estimate(summaries, phase1_n=self._idx1.size,
                                  confidence=confidence)
